"""Serving benchmarks: Poisson traffic through the batched serving engine.

Two scenarios, both writing into ``BENCH_serving.json``:

**Steady state** (PR 2) — a Poisson-arrival mix of variable-shape
requests served through :class:`ServingEngine` wave draining, against
one-request-at-a-time dispatch on the same fused executable.  Asserts
the bucketing invariants: steady-state bucket-hit rate >= 90%, zero
layer re-lowerings after warmup, batched throughput above serial
dispatch.

**Continuous vs wave** (PR 3) — mixed-priority traffic across TWO
registered models replayed twice at the *same offered load*: once with
wave draining (``engine.drain()`` — the whole backlog per gulp, new
arrivals wait out the entire wave) and once with continuous batching
(``engine.step_continuous()`` — arrivals admitted into open buckets
between every scan launch).  Asserts that continuous batching beats
wave draining on p95 latency, that the steady state stays re-lowering
free in both modes, that nothing is shed, and that a sample of replies
is bit-identical to solo runs on the owning model.

**Supervisor overhead** (PR 6) — the same steady-state micro-batches
launched twice on warm paths: raw pool launches (the pre-supervisor hot
path: launch, host-materialize, slice replies) vs launches through the
:class:`~repro.serving.supervisor.LaunchSupervisor` (watchdog timing,
output validation, breaker/heartbeat/straggler bookkeeping, reply
slicing).  Asserts the fault-free overhead stays under 2% and that the
supervised run needed zero retries/degradations.

All timed sections stop the clock only after results are
host-materialized or ``jax.block_until_ready`` has passed; batched-vs-
solo uses best-of-N (the noise-robust estimator) to survive this host's
scheduler jitter.  The p95 comparison is *structural*, not a
micro-timing: a wave over K distinct ``(model, bucket)`` groups holds
every mid-wave arrival for K launches, while continuous admission holds
it for ~1, so the gap survives timer noise.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import SwitchingCompiler
from repro.core.layer import LIFParams, SNNNetwork, random_layer
from repro.core.runtime import network_executable
from repro.core.switching import CompileReport
from repro.serving import ServingEngine

from .common import csv_row

_JSON_PATH = Path(__file__).resolve().parents[1] / "BENCH_serving.json"

#: The traffic mix: (steps, n_in, weight) — four distinct request shapes.
SHAPE_MIX = [(10, 96, 0.4), (18, 72, 0.3), (27, 96, 0.2), (6, 48, 0.1)]
#: Deep narrow feedforward net — the per-timestep lockstep pipeline is many
#: small layer steps, which is exactly the fixed cost batching amortizes.
SIZES = [96, 64, 64, 48, 48, 32, 32, 16, 16, 8]
#: The second tenant for the multi-model scenario: different depth and
#: input width, so it pads and buckets independently of the first.
SIZES_B = [64, 48, 32, 24, 16, 8]

LIF = LIFParams(alpha=0.5, v_th=64.0)


def _parallel_network(sizes, name, seed0=0):
    layers = []
    for i in range(len(sizes) - 1):
        l = random_layer(sizes[i], sizes[i + 1], density=0.3, delay_range=3,
                         seed=seed0 + i, name=f"{name}.l{i}")
        l.lif = LIF
        layers.append(l)
    net = SNNNetwork(layers=layers, name=name)
    compiled = [
        SwitchingCompiler("parallel").compile_layer(l) for l in net.layers
    ]
    return net, CompileReport(layers=compiled)


def poisson_traffic(rng, n_requests, arrival_rate_hz):
    """[(arrival_time_s, (steps, n_in) spike array) ...] in arrival order."""
    shapes = [s[:2] for s in SHAPE_MIX]
    probs = np.array([s[2] for s in SHAPE_MIX])
    probs /= probs.sum()
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate_hz, n_requests))
    out = []
    for t_arr in arrivals:
        steps, n_in = shapes[rng.choice(len(shapes), p=probs)]
        out.append(
            (float(t_arr), (rng.random((steps, n_in)) < 0.25).astype(np.float32))
        )
    return out


def _best_of(fn, iters=7):
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Scenario 1 (PR 2): steady-state wave serving vs one-at-a-time dispatch
# ---------------------------------------------------------------------------

def run_steady_state(*, n_requests: int = 64, arrival_rate_hz: float = 800.0,
                     window_s: float = 0.02, micro_batch: int = 16) -> dict:
    print("\n# serving engine (Poisson traffic, bucketed micro-batches)")
    net, report = _parallel_network(SIZES, "serve")
    rng = np.random.default_rng(0)
    traffic = poisson_traffic(rng, n_requests, arrival_rate_hz)
    true_steps = sum(sp.shape[0] for _, sp in traffic)

    engine = ServingEngine(net, report, micro_batch=micro_batch,
                           min_bucket_steps=8)
    engine.warmup([steps for steps, _, _ in SHAPE_MIX])
    assert engine.pool.relowerings() == 0
    hits0, misses0 = engine.pool.bucket_hits, engine.pool.bucket_misses

    # -- Poisson phase: drain arrival windows, collect serving metrics -------
    window, idx = 0.0, 0
    while idx < len(traffic):
        window += window_s
        while idx < len(traffic) and traffic[idx][0] <= window:
            engine.submit(traffic[idx][1])
            idx += 1
        engine.drain()                      # blocks until the device is done
    stats = engine.stats()
    hits = engine.pool.bucket_hits - hits0
    misses = engine.pool.bucket_misses - misses0
    hit_rate = hits / max(1, hits + misses)

    # -- throughput: batched steady state vs one request at a time -----------
    requests = [sp for _, sp in traffic]

    def batched_once():
        for sp in requests:
            engine.submit(sp)
        engine.drain()

    batched_once()                          # warm the full drain cycle
    t_batched = _best_of(batched_once)
    batched_sps = true_steps / t_batched

    exe = network_executable(net, report)
    solo_inputs = []
    for sp in requests:
        x = np.zeros((sp.shape[0], 1, SIZES[0]), np.float32)
        x[:, 0, : sp.shape[1]] = sp
        solo_inputs.append(x)

    def solo_once():
        for x in solo_inputs:               # host-materialized, like a reply
            exe.run(x)

    solo_once()                             # warm every distinct solo shape
    t_solo = _best_of(solo_once)
    solo_sps = true_steps / t_solo

    speedup = batched_sps / solo_sps
    csv_row("serving_batched_steady_state", t_batched * 1e6,
            f"request_steps_per_s={batched_sps:.0f}")
    csv_row("serving_one_at_a_time", t_solo * 1e6,
            f"request_steps_per_s={solo_sps:.0f}")
    csv_row("serving_batched_speedup", t_batched * 1e6,
            f"x_vs_one_at_a_time={speedup:.2f}")
    csv_row("serving_bucket_hit_rate", 0.0,
            f"steady_state={hit_rate:.3f}")

    assert hit_rate >= 0.9, f"steady-state bucket-hit rate {hit_rate:.3f}"
    assert engine.pool.relowerings() == 0, engine.stats()
    assert batched_sps > solo_sps, (batched_sps, solo_sps)

    return {
        "traffic": {
            "n_requests": n_requests,
            "arrival_rate_hz": arrival_rate_hz,
            "shape_mix": SHAPE_MIX,
            "true_request_steps": true_steps,
        },
        "network": {"sizes": SIZES,
                    "paradigms": ["parallel"] * (len(SIZES) - 1)},
        "poisson_phase": {
            "p50_latency_ms": stats["p50_ms"],
            "p95_latency_ms": stats["p95_ms"],
            "mean_queue_wait_ms": stats["mean_queue_wait_ms"],
            "mean_batch_occupancy": stats["mean_batch_occupancy"],
            "padding_overhead": stats["padding_overhead"],
            "bucket_hit_rate": hit_rate,
        },
        "throughput": {
            "batched_request_steps_per_s": batched_sps,
            "one_at_a_time_request_steps_per_s": solo_sps,
            "speedup_batched_vs_one_at_a_time": speedup,
        },
        "relowerings_after_warmup": engine.pool.relowerings(),
    }


# ---------------------------------------------------------------------------
# Scenario 2 (PR 3): continuous batching vs wave draining, two models,
# mixed priorities, equal offered load
# ---------------------------------------------------------------------------

#: (steps, model, priority, deadline_ms) mix for the multi-tenant scenario.
#: Priority 2 = interactive (generous deadline, must never shed here),
#: priority 0 = bulk.  Two step shapes per model -> 4 (model, bucket)
#: groups, so a full wave is always >= 4 scan launches.
MIX_PRIO = [
    (10, "a", 0, None, 0.35),
    (18, "a", 2, 2000.0, 0.15),
    (12, "b", 0, None, 0.35),
    (20, "b", 2, 2000.0, 0.15),
]


def _prio_traffic(rng, n_requests, arrival_rate, widths, burst=16):
    """Initial burst of ``burst`` requests at t=0, then Poisson arrivals.

    ``arrival_rate`` is in requests per virtual launch unit.  The burst
    seeds a backlog so wave draining actually forms multi-launch waves —
    the regime the two modes differ in.
    """
    probs = np.array([m[4] for m in MIX_PRIO])
    probs /= probs.sum()
    arrivals = np.concatenate([
        np.zeros(burst),
        np.cumsum(rng.exponential(1.0 / arrival_rate, n_requests - burst)),
    ])
    out = []
    for t_arr in arrivals:
        steps, model, prio, deadline, _ = MIX_PRIO[
            rng.choice(len(MIX_PRIO), p=probs)
        ]
        n_in = widths[model]
        sp = (rng.random((steps, n_in)) < 0.25).astype(np.float32)
        out.append((float(t_arr), model, prio, deadline, sp))
    return out


def _virtual_replay(mode, traffic, widths, models, steps_mix, micro_batch):
    """Replay the arrival trace in virtual time; every launch costs 1 unit.

    The two modes differ only in scheduling structure, so the comparison
    is made in *virtual launch units*: arrivals happen at the trace's
    virtual timestamps and each fused-scan launch advances the clock by
    exactly one unit.  The scans still execute for real (warm-path
    counters, re-lowering invariants, and bit-identical replies are all
    live), but the latency arithmetic is deterministic — independent of
    this host's scheduler jitter — and reproducible from the trace seed.

    ``mode="wave"``: snapshot the backlog, form all micro-batches, run
    them back-to-back; arrivals during the wave wait for the whole wave.
    ``mode="continuous"``: admit arrivals into open buckets, launch the
    single most urgent bucket, look at the queue again.
    """
    from repro.serving import (
        BucketKey, ExecutablePool, RequestQueue, ShapeBucketingScheduler,
    )

    q = RequestQueue()
    sched = ShapeBucketingScheduler(
        widths["a"], micro_batch=micro_batch, min_bucket_steps=8
    )
    pool = ExecutablePool()
    for name, (net, rep) in models.items():
        sched.set_model_input(name, widths[name])
        pool.register(net, rep, name)
        pool.warmup(
            [
                BucketKey(sched.bucket_steps(s), widths[name], micro_batch)
                for s in steps_mix[name]
            ],
            name=name,
        )
    assert pool.relowerings() == 0

    n = len(traffic)
    sim, i = 0.0, 0
    arrival_t, latency, replies, occupancy = {}, {}, {}, []

    def submit_due():
        nonlocal i
        while i < n and traffic[i][0] <= sim:
            _, model, prio, deadline, sp = traffic[i]
            req = q.submit(sp, model=model, priority=prio,
                           deadline_ms=deadline)
            arrival_t[req.request_id] = (traffic[i][0], i)
            i += 1

    def run_mb(mb):
        nonlocal sim
        host = [np.asarray(z) for z in pool.run_microbatch(mb)]
        sim += 1.0                      # one launch == one virtual time unit
        occupancy.append(len(mb.requests))
        for b, req in enumerate(mb.requests):
            latency[req.request_id] = sim - arrival_t[req.request_id][0]
            replies[req.request_id] = [z[: req.steps, b] for z in host]

    while i < n or len(q) or sched.has_open():
        submit_due()
        if q.empty() and not sched.has_open():
            sim = traffic[i][0]         # idle: jump to the next arrival
            continue
        if mode == "wave":
            for mb in sched.form_microbatches(q.pop_all()):
                run_mb(mb)              # no admission until the wave completes
        else:
            for req in q.pop_all():
                sched.admit(req)
            mb = sched.pop_launchable()
            if mb is not None:
                run_mb(mb)

    assert pool.relowerings() == 0
    idx_of = {rid: idx for rid, (_, idx) in arrival_t.items()}
    return {
        "latency": latency,             # rid -> launches waited
        "replies": replies,
        "idx_of": idx_of,
        "launches": len(occupancy),
        "mean_occupancy": float(np.mean(occupancy)),
    }


def _p95(values):
    return float(np.percentile(np.asarray(values), 95))


def run_continuous_vs_wave(*, n_requests: int = 96,
                           micro_batch: int = 4,
                           arrivals_per_launch: float = 3.0) -> dict:
    print("\n# continuous batching vs wave draining "
          "(two models, mixed priorities)")
    net_a, rep_a = _parallel_network(SIZES, "tenant-a")
    net_b, rep_b = _parallel_network(SIZES_B, "tenant-b", seed0=100)
    models = {"a": (net_a, rep_a), "b": (net_b, rep_b)}
    widths = {"a": SIZES[0], "b": SIZES_B[0]}
    steps_mix = {"a": [10, 18], "b": [12, 20]}

    # offered load: ~3 arrivals per launch against a capacity of
    # micro_batch=4 per launch (~75%), so backlogs form and a wave holds
    # several launches — the regime where the two modes differ
    rng = np.random.default_rng(7)
    traffic = _prio_traffic(rng, n_requests, arrivals_per_launch, widths)

    runs, sections = {}, {}
    for mode in ("wave", "continuous"):
        out = _virtual_replay(mode, traffic, widths, models, steps_mix,
                              micro_batch)
        assert len(out["latency"]) == n_requests, (mode, len(out["latency"]))
        lat_all = list(out["latency"].values())
        by_prio = {}
        for rid, lat in out["latency"].items():
            prio = traffic[out["idx_of"][rid]][2]
            by_prio.setdefault(prio, []).append(lat)
        runs[mode] = out
        sections[mode] = {
            "p50_latency_launches": float(np.percentile(lat_all, 50)),
            "p95_latency_launches": _p95(lat_all),
            "p95_by_priority_launches": {
                str(p): _p95(v) for p, v in sorted(by_prio.items())
            },
            "mean_batch_occupancy": out["mean_occupancy"],
            "launches": out["launches"],
        }
        s = sections[mode]
        print(f"  {mode:11s}: p50 {s['p50_latency_launches']:5.1f}  "
              f"p95 {s['p95_latency_launches']:5.1f}  "
              f"prio-2 p95 {s['p95_by_priority_launches']['2']:5.1f} "
              f"(launches, virtual)  occupancy "
              f"{s['mean_batch_occupancy']:.2f}  "
              f"{s['launches']} launches total")

    # -- replies bit-identical to solo runs (sample both models) -------------
    checked = 0
    cont = runs["continuous"]
    for rid, reply in cont["replies"].items():
        if checked >= 8:
            break
        _, model, _, _, sp = traffic[cont["idx_of"][rid]]
        net, rep = models[model]
        x = np.zeros((sp.shape[0], 1, widths[model]), np.float32)
        x[:, 0, : sp.shape[1]] = sp
        solo = network_executable(net, rep).run(x)
        for got, want in zip(reply, solo):
            np.testing.assert_array_equal(got, want[:, 0])
        checked += 1
    assert checked > 0

    p95_wave = sections["wave"]["p95_latency_launches"]
    p95_cont = sections["continuous"]["p95_latency_launches"]
    hi_wave = sections["wave"]["p95_by_priority_launches"]["2"]
    hi_cont = sections["continuous"]["p95_by_priority_launches"]["2"]
    csv_row("serving_wave_p95", p95_wave, "unit=launches mode=wave")
    csv_row("serving_continuous_p95", p95_cont,
            "unit=launches mode=continuous")
    csv_row("serving_continuous_gain", 0.0,
            f"p95_wave_over_continuous={p95_wave / p95_cont:.2f}")
    csv_row("serving_continuous_gain_prio2", 0.0,
            f"p95_wave_over_continuous={hi_wave / hi_cont:.2f}")

    # THE acceptance property: same offered load (identical trace),
    # lower tail latency — overall and for the interactive class — at
    # equal throughput (the same 96 requests in no more launches)
    assert p95_cont < p95_wave, (p95_cont, p95_wave)
    assert hi_cont < hi_wave, (hi_cont, hi_wave)
    assert runs["continuous"]["launches"] <= runs["wave"]["launches"], (
        runs["continuous"]["launches"], runs["wave"]["launches"]
    )

    print(f"  continuous p95 is {p95_wave / p95_cont:.2f}x lower than wave "
          f"({hi_wave / hi_cont:.2f}x for priority 2) at the same offered "
          f"load, in {runs['continuous']['launches']} vs "
          f"{runs['wave']['launches']} launches")
    return {
        "traffic": {
            "n_requests": n_requests,
            "arrivals_per_launch": arrivals_per_launch,
            "mix": [
                {"steps": s, "model": m, "priority": p, "deadline_ms": d,
                 "weight": w}
                for s, m, p, d, w in MIX_PRIO
            ],
        },
        "models": {"a": SIZES, "b": SIZES_B},
        "micro_batch": micro_batch,
        "latency_unit": "scan launches (virtual time; deterministic)",
        "wave": sections["wave"],
        "continuous": sections["continuous"],
        "p95_wave_over_continuous": p95_wave / p95_cont,
        "p95_wave_over_continuous_prio2": hi_wave / hi_cont,
        "replies_checked_bit_identical": checked,
    }


# ---------------------------------------------------------------------------
# Scenario 3 (PR 6): launch-supervisor overhead on the fault-free path
# ---------------------------------------------------------------------------

def run_supervisor_overhead(*, n_requests: int = 48, micro_batch: int = 8,
                            reps: int = 40, trials: int = 3) -> dict:
    """Raw pool launches vs supervised launches on identical warm batches.

    The supervisor adds per-launch watchdog timing, output-validation
    consumption (launches self-check in-graph: the jitted program
    reduces every train to one "all entries 0/1" scalar, so the
    fault-free path reads a flag instead of re-scanning host arrays),
    breaker/heartbeat/straggler bookkeeping, and reply trimming.  This
    scenario bounds that cost on the path that matters — fault-free
    steady state — at under 2%.

    Measuring a sub-2% delta on a ~25 ms loop with multi-ms OS jitter
    needs a robust estimator: raw and supervised launches of the *same*
    micro-batch are timed back-to-back (shared background/thermal
    state), each micro-batch's time is taken as the **median over
    ``reps`` interleaved samples** (kills scheduler spikes), the loop
    times are the sums of those per-batch medians, and the reported
    overhead is the **median over ``trials`` independent trials** of
    that ratio.
    """
    print("\n# launch-supervisor overhead (fault-free steady state)")
    from repro.serving import (
        BucketKey, ExecutablePool, LaunchSupervisor, RequestQueue,
        ShapeBucketingScheduler,
    )

    net, report = _parallel_network(SIZES, "supervised")
    rng = np.random.default_rng(3)
    traffic = poisson_traffic(rng, n_requests, 800.0)

    q = RequestQueue()
    sched = ShapeBucketingScheduler(
        SIZES[0], micro_batch=micro_batch, min_bucket_steps=8
    )
    pool = ExecutablePool()
    pool.register(net, report)
    pool.warmup([
        BucketKey(sched.bucket_steps(s), SIZES[0], micro_batch)
        for s, _, _ in SHAPE_MIX
    ])
    for _, sp in traffic:
        sched.admit(q.submit(sp))
    mbs = []
    while True:
        mb = sched.pop_launchable(force=True)
        if mb is None:
            break
        mbs.append(mb)
    supervisor = LaunchSupervisor(pool, watchdog_s=5.0)

    def raw_mb(mb):
        # the pre-supervisor hot path: launch, host-materialize, trim
        host = [np.asarray(z) for z in pool.run_microbatch(mb)]
        for b, req in enumerate(mb.requests):
            [z[: req.steps, b] for z in host]

    for mb in mbs:                      # both paths fully warm
        raw_mb(mb)
        supervisor.run(mb)

    def _median(vals):
        vals = sorted(vals)
        return vals[len(vals) // 2]

    trial_results = []
    for _ in range(trials):
        raw_t = [[] for _ in mbs]
        sup_t = [[] for _ in mbs]
        for rep in range(reps):
            for i, mb in enumerate(mbs):
                # alternate which path goes first so neither always
                # inherits the other's cache state
                order = (raw_mb, supervisor.run) if rep % 2 == 0 else (
                    supervisor.run, raw_mb)
                slots = (raw_t, sup_t) if rep % 2 == 0 else (sup_t, raw_t)
                t0 = time.perf_counter()
                order[0](mb)
                t1 = time.perf_counter()
                order[1](mb)
                t2 = time.perf_counter()
                slots[0][i].append(t1 - t0)
                slots[1][i].append(t2 - t1)
        trial_raw = sum(_median(v) for v in raw_t)
        trial_sup = sum(_median(v) for v in sup_t)
        trial_results.append((trial_raw, trial_sup))
    trial_results.sort(key=lambda p: p[1] / p[0])   # median trial by ratio
    t_raw, t_sup = trial_results[len(trial_results) // 2]
    overhead = t_sup / t_raw - 1.0

    csv_row("serving_raw_launch_loop", t_raw * 1e6,
            f"microbatches={len(mbs)}")
    csv_row("serving_supervised_launch_loop", t_sup * 1e6,
            f"microbatches={len(mbs)}")
    csv_row("serving_supervisor_overhead", 0.0,
            f"fault_free_fraction={overhead:.4f}")
    print(f"  raw {t_raw * 1e3:.2f} ms  supervised {t_sup * 1e3:.2f} ms  "
          f"overhead {overhead * 100:.2f}% over {len(mbs)} micro-batches")

    counters = supervisor.counters
    assert counters["retries"] == 0, counters
    assert counters["degraded_launches"] == 0, counters
    assert counters["quarantined"] == 0, counters
    assert counters["watchdog_stalls"] == 0, counters
    assert overhead < 0.02, f"supervisor overhead {overhead:.4f} >= 2%"

    return {
        "n_requests": n_requests,
        "micro_batches": len(mbs),
        "micro_batch": micro_batch,
        "raw_launch_loop_s": t_raw,
        "supervised_launch_loop_s": t_sup,
        "overhead_fraction": overhead,
        "budget_fraction": 0.02,
        "supervised_counters": {
            k: counters[k]
            for k in ("launch_attempts", "retries", "degraded_launches",
                      "quarantined", "watchdog_stalls",
                      "validation_failures")
        },
    }


def run(*, n_requests: int = 64, arrival_rate_hz: float = 800.0,
        window_s: float = 0.02, micro_batch: int = 16) -> dict:
    result = {
        "steady_state": run_steady_state(
            n_requests=n_requests, arrival_rate_hz=arrival_rate_hz,
            window_s=window_s, micro_batch=micro_batch,
        ),
        "continuous_vs_wave": run_continuous_vs_wave(),
        "supervisor_overhead": run_supervisor_overhead(),
    }
    _JSON_PATH.write_text(json.dumps(result, indent=2) + "\n")
    ss = result["steady_state"]["throughput"]
    print(f"wrote {_JSON_PATH.name} "
          f"(batched {ss['speedup_batched_vs_one_at_a_time']:.2f}x vs "
          f"one-at-a-time; continuous p95 "
          f"{result['continuous_vs_wave']['p95_wave_over_continuous']:.2f}x "
          f"lower than wave; supervisor overhead "
          f"{result['supervisor_overhead']['overhead_fraction'] * 100:.2f}%)")
    return result


if __name__ == "__main__":
    run()
