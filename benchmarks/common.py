"""Shared benchmark utilities."""
from __future__ import annotations

import time


def timeit(fn, *, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line
