"""Render EXPERIMENTS.md tables from the dry-run results JSONL.

    PYTHONPATH=src python -m benchmarks.roofline_table \
        benchmarks/data/dryrun/results.jsonl
"""
from __future__ import annotations

import json
import sys
from collections import OrderedDict


def fmt_bytes(n):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def load(path):
    recs = OrderedDict()
    for line in open(path):
        r = json.loads(line)
        key = (r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline"))
        recs[key] = r  # later lines win (re-runs)
    return recs


def dryrun_table(recs, mesh):
    print(f"\n### Dry-run, {mesh} mesh "
          f"({'2x16x16=512' if mesh == 'multi' else '16x16=256'} chips)\n")
    print("| arch | shape | status | compile_s | HBM args/dev | temp/dev | "
          "collectives (count) |")
    print("|---|---|---|---|---|---|---|")
    for (a, s, m, v), r in recs.items():
        if m != mesh or v != "baseline":
            continue
        if r["status"] == "skipped":
            print(f"| {a} | {s} | SKIP (sub-quadratic-only shape) | — | — | — | — |")
            continue
        if r["status"] == "error":
            print(f"| {a} | {s} | ERROR: {r['error'][:60]} | — | — | — | — |")
            continue
        ma = r.get("memory_analysis", {})
        args = fmt_bytes(ma.get("argument_size_in_bytes", 0))
        temp = fmt_bytes(ma.get("temp_size_in_bytes", 0))
        cc = r.get("collective_counts", {})
        cstr = " ".join(f"{k.split('-')[0]}:{v2}" for k, v2 in cc.items() if v2)
        print(f"| {a} | {s} | ok | {r['compile_s']} | {args} | {temp} | "
              f"{cstr or 'none'} |")


def roofline_table(recs):
    print("\n### Roofline terms (single-pod 16x16; per-device, per-step)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant | "
          "roofline_frac | model/HLO flops |")
    print("|---|---|---|---|---|---|---|---|")
    for (a, s, m, v), r in recs.items():
        if m != "single" or v != "baseline" or r["status"] != "ok":
            continue
        print(f"| {a} | {s} | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
              f"{r['collective_s']:.3e} | **{r['dominant']}** | "
              f"{r['roofline_fraction']:.3f} | {r['model_flops_ratio']:.2f} |")


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "benchmarks/data/dryrun/results.jsonl"
    recs = load(path)
    n_ok = sum(r["status"] == "ok" for r in recs.values())
    n_skip = sum(r["status"] == "skipped" for r in recs.values())
    n_err = sum(r["status"] == "error" for r in recs.values())
    print(f"{len(recs)} cells: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    dryrun_table(recs, "single")
    dryrun_table(recs, "multi")
    roofline_table(recs)


if __name__ == "__main__":
    main()
