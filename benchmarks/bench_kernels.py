"""Pallas kernel micro-bench (interpret mode on CPU; TPU is the target).

us_per_call is the CPU-interpret wall time — meaningful only as a
regression guard; the TPU roofline for these kernels is in §Roofline.
Also runs the SNN runtime throughput comparison (serial VPU path vs
parallel MXU path), the runtime-level analogue of Fig 5.
"""
from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import random_layer
from repro.core.layer import LIFParams
from repro.core.runtime import run_parallel, run_reference, run_serial
from repro.kernels.lif_update import lif_update
from repro.kernels.spike_wdm_matmul import spike_wdm_matmul

from .common import csv_row, timeit


def run():
    print("\n# Pallas kernels (interpret mode on CPU host)")
    # compiled kernels on TPU; off-TPU force the interpreter so the bench
    # still measures the kernel bodies (auto mode would run the jnp refs)
    interp = None if jax.default_backend() == "tpu" else True
    rng = np.random.default_rng(0)
    for (m, k, n) in [(128, 512, 128), (512, 2048, 128)]:
        a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        x = jnp.asarray(rng.integers(0, 2, (k, n)), jnp.int8)
        us = timeit(
            lambda: spike_wdm_matmul(a, x, interpret=interp).block_until_ready(),
            iters=5,
        )
        macs = m * k * n
        csv_row(f"kernel_wdm_matmul_{m}x{k}x{n}", us,
                f"gmacs_per_s={macs/us/1e3:.2f}")
    i = jnp.asarray(rng.normal(size=(1024, 128)), jnp.float32)
    v = jnp.zeros((1024, 128), jnp.float32)
    z = jnp.zeros((1024, 128), jnp.float32)
    us = timeit(
        lambda: lif_update(
            i, v, z, alpha=0.5, v_th=1.0, interpret=interp
        )[0].block_until_ready(),
        iters=5,
    )
    csv_row("kernel_lif_update_1024x128", us,
            f"gneuron_updates_per_s={1024*128/us/1e3:.2f}")
    from repro.kernels.ssd_chunk import ssd_chunk
    q, h, p_, n_ = 256, 24, 64, 128   # mamba2-130m production chunk
    xs = jnp.asarray(rng.normal(size=(q, h, p_)), jnp.float32)
    bs = jnp.asarray(rng.normal(size=(q, h, n_)), jnp.float32)
    cs = jnp.asarray(rng.normal(size=(q, h, n_)), jnp.float32)
    las = jnp.asarray(-abs(rng.normal(size=(q, h)) * 0.1), jnp.float32)
    us = timeit(lambda: ssd_chunk(xs, bs, cs, las)[0].block_until_ready(),
                iters=3)
    flops = h * (2 * q * q * n_ + 2 * q * q * p_ + 2 * q * n_ * p_)
    csv_row(f"kernel_ssd_chunk_{q}x{h}x{p_}x{n_}", us,
            f"gflops_per_s={flops/us/1e3:.2f}")

    print("\n# SNN runtime throughput (both paradigms, batch=16, T=50)")
    lif = LIFParams(alpha=0.5, v_th=64.0)
    layer = random_layer(256, 256, 0.5, 4, seed=0)
    layer.lif = lif
    spikes = (rng.random((50, 16, 256)) < 0.2).astype(np.float32)
    for name, fn in (
        ("runtime_serial", lambda: run_serial(layer, spikes, lif)),
        ("runtime_parallel", lambda: run_parallel(layer, spikes, lif)),
        ("runtime_reference", lambda: run_reference(layer, spikes, lif)),
    ):
        us = timeit(fn, warmup=1, iters=3)
        steps_per_s = 50 * 16 / (us / 1e6)
        csv_row(name, us, f"batch_timesteps_per_s={steps_per_s:.0f}")


if __name__ == "__main__":
    run()
