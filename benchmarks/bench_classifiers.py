"""Fig 4 — accuracy comparison among the 12 classifiers on the 16k dataset.

Paper: Adaptive Boost wins at 91.69%.  ``--seeds N`` reproduces the red
accuracy ranges (the paper trains with 20 seeds; default here is 3 to keep
the harness quick — pass --seeds 20 for the full error bars).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import load_or_generate
from repro.core.classifiers import zoo

from .common import csv_row


def run(seeds: int = 3, fast: bool = False):
    ds = load_or_generate()
    print(f"\n# Fig 4: 12-classifier comparison on the {len(ds)}-layer "
          f"dataset ({seeds} seed(s); paper reports AdaBoost 91.69%)")
    results = {}
    for name in zoo():
        accs, t_train = [], 0.0
        for seed in range(seeds):
            (Xtr, ytr), (Xte, yte) = ds.split(0.2, seed=seed)
            if fast:
                Xtr, ytr = Xtr[:2000], ytr[:2000]
            clf = zoo(seed=seed)[name]()
            t0 = time.perf_counter()
            clf.fit(Xtr, ytr)
            t_train += time.perf_counter() - t0
            accs.append(clf.score(Xte, yte))
        accs = np.asarray(accs)
        results[name] = (accs.mean(), accs.min(), accs.max(), t_train / seeds)

    order = sorted(results, key=lambda n: -results[n][0])
    for name in order:
        mean, lo, hi, t = results[name]
        print(f"  {name:<16s} acc={mean*100:6.2f}%  range=[{lo*100:.2f}, "
              f"{hi*100:.2f}]  train={t:.1f}s")
    best = order[0]
    ada = results["adaboost"][0]
    print(f"  best={best} ({results[best][0]*100:.2f}%); "
          f"adaboost={ada*100:.2f}% (paper: 91.69%)")
    for name in order:
        csv_row(f"fig4_{name}", results[name][3] * 1e6,
                f"acc={results[name][0]:.4f}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    run(args.seeds, args.fast)
