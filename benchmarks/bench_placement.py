"""Placement benchmark: searched mapping vs naive round-robin.

Tiles each fixture graph onto the core grid, places it twice — the
greedy + local-search mapper vs the round-robin baseline — and compares
estimated NoC cut traffic (spikes x hops per timestep, the mapper's
objective).  The acceptance property of the placement engine is that the
search wins on every fixture; ``tests/test_placement.py`` pins it as a
test and this benchmark quantifies it, merging a ``placement`` section
into ``BENCH_network.json``:

    {"placement": {"<fixture>": {"round_robin": ..., "greedy": ...,
                                 "refined": ..., "improvement": ...,
                                 "search_us": ...}}}
"""
from __future__ import annotations

import json
from pathlib import Path

import dataclasses

import numpy as np

from repro.core import Population, random_projection
from repro.core.hw import DEFAULT_S2
from repro.core.layer import LIFParams, SNNNetwork
from repro.placement import (
    CoreGrid, estimate_traffic, greedy_place, refine, round_robin_place,
    tile_network,
)

from .common import csv_row, timeit

_JSON_PATH = Path(__file__).resolve().parents[1] / "BENCH_network.json"

LIF = LIFParams(alpha=0.5, v_th=64.0)

#: Fixture graphs: (populations, projections, seed, tile budget).  Both
#: are recurrent; "ring" adds a larger population mix so round-robin's
#: locality blindness costs more.
FIXTURES = {
    "recurrent-mlp": (
        [("in", 24), ("h1", 40), ("h2", 36), ("out", 10)],
        [("in", "h1", 0.3, 2), ("h1", "h2", 0.3, 2), ("h2", "h1", 0.2, 3),
         ("h2", "h2", 0.2, 2), ("h2", "out", 0.5, 2)],
        11, 10,
    ),
    "ring": (
        [("in", 20), ("a", 30), ("b", 30), ("c", 30)],
        [("in", "a", 0.3, 2), ("a", "b", 0.3, 2), ("b", "c", 0.3, 2),
         ("c", "a", 0.3, 2), ("c", "c", 0.15, 3)],
        22, 8,
    ),
}


def _merge_json(update: dict) -> None:
    data = {}
    if _JSON_PATH.exists():
        try:
            data = json.loads(_JSON_PATH.read_text())
        except json.JSONDecodeError:
            data = {}
    data.update(update)
    _JSON_PATH.write_text(json.dumps(data, indent=2) + "\n")


def _build(name):
    pop_spec, proj_spec, seed, budget = FIXTURES[name]
    rng = np.random.default_rng(seed)
    pops = {n: Population(n, s) for n, s in pop_spec}
    projs = []
    for pre, post, density, delay_range in proj_spec:
        p = random_projection(
            pops[pre], pops[post], density, delay_range,
            seed=int(rng.integers(0, 2**31)),
        )
        p.lif = LIF
        projs.append(p)
    net = SNNNetwork(
        populations=list(pops.values()), projections=projs, name=name,
    )
    return tile_network(net, max_neurons=budget)


def run() -> dict:
    section = {}
    for name in FIXTURES:
        tiled = _build(name)
        # cap each core at ~2 tiles so the mapper must actually spread
        # (with the full 255-neuron budget everything co-locates and both
        # placers trivially reach zero cut traffic); the untiled input
        # population sets the floor — it must still fit somewhere
        biggest = max(s.size for s in tiled.tile_slices.values())
        hw = dataclasses.replace(
            DEFAULT_S2, max_neurons_per_pe=biggest + tiled.max_neurons
        )
        grid = CoreGrid(rows=4, cols=4, hw=hw)
        traffic = estimate_traffic(tiled)
        rr = round_robin_place(tiled, grid, traffic)
        greedy = greedy_place(tiled, grid, traffic)
        refined = refine(greedy, tiled, grid, traffic)
        us = timeit(
            lambda: refine(
                greedy_place(tiled, grid, traffic), tiled, grid, traffic
            ),
            warmup=1, iters=5,
        )
        assert refined.cost < rr.cost, (
            f"{name}: search ({refined.cost:.2f}) must beat round-robin "
            f"({rr.cost:.2f})"
        )
        improvement = 1.0 - refined.cost / rr.cost if rr.cost else 0.0
        section[name] = {
            "tiles": len(tiled.network.populations),
            "blocks": len(tiled.network.projections),
            "round_robin": round(rr.cost, 3),
            "greedy": round(greedy.cost, 3),
            "refined": round(refined.cost, 3),
            "improvement": round(improvement, 4),
            "search_us": round(us, 1),
        }
        csv_row(
            f"placement_{name}", us,
            f"cut traffic rr={rr.cost:.1f} -> search={refined.cost:.1f} "
            f"(-{improvement:.0%})",
        )
    section["scaffold-measured"] = _scaffold_measured()
    _merge_json({"placement": section})
    return section


def _scaffold_measured() -> dict:
    """Place a generated cerebellum with *measured* activity rates.

    Runs a scaffold slice through the fused executor, profiles the trains
    (:func:`profile_run`), and feeds the measured per-population rates
    into the traffic estimate — asserting the measured-rate cut-traffic
    estimate actually differs from the uniform-rate default (the profiler
    plumbing is live, not dropped on the floor) and that the placement
    respects an activity budget sized from the measurement.
    """
    from repro.core.runtime import profile_run
    from repro.placement import check_activity_budgets, place_network
    from repro.scaffold import build_cerebellum, compile_scaffold

    sc = build_cerebellum(800, seed=5)
    report = compile_scaffold(sc)
    spikes = sc.stimulus(16, 2, seed=6)
    _, profile = profile_run(sc.network, report, spikes)
    rates = profile.rates()

    tiled = tile_network(sc.network, max_neurons=120)
    biggest = max(s.size for s in tiled.tile_slices.values())
    hw = dataclasses.replace(DEFAULT_S2, max_neurons_per_pe=biggest + 120)
    grid = CoreGrid(rows=4, cols=4, hw=hw)

    uniform = estimate_traffic(tiled)
    measured = estimate_traffic(tiled, rates)
    assert not np.allclose(uniform, measured), (
        "measured rates must change the traffic estimate"
    )
    placed = place_network(tiled, grid, rates)
    # activity budgets: the measured per-core packet load must pass a
    # budget sized above the observed peak core (and the dimension binds
    # — an impossibly tight budget trips it)
    per_core = check_activity_budgets(
        tiled, placed.assignment, grid.budget, rates
    )
    from repro.core.hw import BudgetExceeded, PEBudget

    peak = max(per_core.values())
    tight = dataclasses.replace(grid.budget, max_in_packets=peak / 2)
    try:
        check_activity_budgets(tiled, placed.assignment, tight, rates)
        raise AssertionError("tight activity budget must trip")
    except BudgetExceeded:
        pass
    drift = float(
        np.abs(measured - uniform).sum() / max(uniform.sum(), 1e-9)
    )
    csv_row(
        "placement_scaffold-measured", 0.0,
        f"traffic drift uniform->measured {drift:.0%}, "
        f"peak core {peak:.1f} pkts/step",
    )
    return {
        "tiles": len(tiled.network.populations),
        "uniform_traffic": round(float(uniform.sum()), 3),
        "measured_traffic": round(float(measured.sum()), 3),
        "traffic_drift": round(drift, 4),
        "cost_measured_rates": round(placed.cost, 3),
        "peak_core_in_packets": round(peak, 3),
        "rates": {k: round(v, 5) for k, v in sorted(rates.items())},
    }


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
